"""Attention (chunked vs full, GQA) and SSD (chunked vs naive recurrence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import ssm as S


def test_chunked_attention_matches_full():
    b, s, h, d = 2, 64, 4, 16
    kq = jax.random.PRNGKey(0)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, d), jnp.float32)
    causal = jnp.tril(jnp.ones((s, s), bool))
    bias = jnp.where(causal, 0.0, A.NEG_INF)[None, None, None]
    full = A._full_attention(q, k, v, bias)
    import repro.models.attention as attn_mod
    old_q, old_kv = attn_mod.Q_CHUNK, attn_mod.KV_CHUNK
    attn_mod.Q_CHUNK, attn_mod.KV_CHUNK = 16, 16
    try:
        chunked = A._chunked_causal_attention(q, k, v)
    finally:
        attn_mod.Q_CHUNK, attn_mod.KV_CHUNK = old_q, old_kv
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_gqa_equals_repeated_kv_mha():
    """GQA with Hkv<H == MHA with kv heads repeated."""
    b, s, h, hkv, d = 1, 8, 4, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d), jnp.float32)
    causal = jnp.tril(jnp.ones((s, s), bool))
    bias = jnp.where(causal, 0.0, A.NEG_INF)[None, None, None]
    out_gqa = A._full_attention(q, k, v, bias)
    k_rep = jnp.repeat(k, h // hkv, axis=2)
    v_rep = jnp.repeat(v, h // hkv, axis=2)
    out_mha = A._full_attention(q, k_rep, v_rep, bias)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on i-j (shift both -> same scores)."""
    from repro.models.common import rope
    b, s, h, d = 1, 6, 1, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d), jnp.float32)
    pos0 = jnp.arange(s)[None]
    pos5 = pos0 + 5
    s0 = jnp.einsum("bshd,bthd->bst", rope(q, pos0, 1e4), rope(k, pos0, 1e4))
    s5 = jnp.einsum("bshd,bthd->bst", rope(q, pos5, 1e4), rope(k, pos5, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s5), atol=1e-3)


def _naive_ssd(x, a, bm, cm):
    """O(L^2)-free naive recurrence oracle: sequential state update."""
    bsz, l, h, p = x.shape
    n = bm.shape[-1]
    state = np.zeros((bsz, h, p, n), np.float32)
    ys = []
    for t in range(l):
        da = np.exp(np.asarray(a[:, t], np.float32))       # (B, H)
        state = state * da[:, :, None, None] + np.einsum(
            "bhp,bhn->bhpn", np.asarray(x[:, t], np.float32),
            np.asarray(bm[:, t], np.float32))
        ys.append(np.einsum("bhpn,bhn->bhp", state,
                            np.asarray(cm[:, t], np.float32)))
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive_recurrence(chunk):
    bsz, l, h, p, n = 2, 32, 3, 4, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (bsz, l, h, p), jnp.float32) * 0.5
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (bsz, l, h))) * 0.3
    bm = jax.random.normal(jax.random.PRNGKey(2), (bsz, l, h, n)) * 0.5
    cm = jax.random.normal(jax.random.PRNGKey(3), (bsz, l, h, n)) * 0.5
    y, final = S._ssd_chunked(x, a, bm, cm, chunk)
    y_ref = _naive_ssd(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


def test_ssd_gradients_finite():
    bsz, l, h, p, n = 1, 16, 2, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (bsz, l, h, p)) * 0.5
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (bsz, l, h)))
    bm = jax.random.normal(jax.random.PRNGKey(2), (bsz, l, h, n))
    cm = jax.random.normal(jax.random.PRNGKey(3), (bsz, l, h, n))

    def loss(x):
        y, _ = S._ssd_chunked(x, a, bm, cm, 8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.isfinite(g).all())


def test_causal_conv_is_causal():
    b, l, c, w = 1, 10, 3, 4
    x = jnp.zeros((b, l, c)).at[:, 5].set(1.0)
    kern = jnp.ones((c, w))
    y = S._causal_conv(x, kern, jnp.zeros((c,)))
    assert float(jnp.abs(y[:, :5]).sum()) == 0.0  # nothing before t=5
    assert float(jnp.abs(y[:, 5]).sum()) > 0
