"""Quantization substrate: quant/dequant error bounds, packing, pytrees."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, strategies as st

from repro.quant.apply import (SegmentedParams, apply_plan_stacked,
                               plan_segments, quantize_tree, tree_nbytes)
from repro.quant.qtypes import QTensor
from repro.quant.quantize import (dequantize, quantize, quantize_int4,
                                  quantize_int8, quantize_ternary,
                                  unpack_int4)
from repro.core.policy import BlockDecision, QuantPlan


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def test_int8_roundtrip_error():
    w = _rand((64, 256))
    q = quantize_int8(w)
    err = jnp.abs(dequantize(q, jnp.float32) - w)
    # per-group absmax/127 is the max step; scales are bf16 (+0.4% rel)
    g = w.reshape(64, 2, 128)
    absmax = jnp.repeat(jnp.max(jnp.abs(g), -1), 128, -1).reshape(64, 256)
    bound = absmax / 127.0 * 0.5 + absmax * 0.005 + 1e-6
    assert bool(jnp.all(err <= bound))


def test_int4_pack_unpack_roundtrip():
    vals = jnp.arange(-7, 8, dtype=jnp.int8)
    w = jnp.tile(vals, 256)[: 128 * 16].reshape(16, 128).astype(jnp.float32)
    q = quantize_int4(w * 0.01)
    assert q.data.shape == (16, 64)  # packed two per byte
    unpacked = unpack_int4(q.data)
    assert unpacked.shape == (16, 128)
    assert int(jnp.max(jnp.abs(unpacked))) <= 7


@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 10.0))
def test_int4_error_bound(rows8, groups, scale):
    rows = rows8 * 4
    k = groups * 128
    w = _rand((rows, k), seed=rows * 31 + groups, scale=scale)
    q = quantize_int4(w)
    err = jnp.abs(dequantize(q, jnp.float32) - w)
    g = w.reshape(rows, groups, 128)
    absmax = jnp.repeat(jnp.max(jnp.abs(g), -1), 128, -1).reshape(rows, k)
    bound = absmax / 7.0 * 0.5 + absmax * 0.005 + 1e-5  # bf16 scales
    assert bool(jnp.all(err <= bound))


def test_ternary_values_and_scale():
    w = _rand((32, 128), seed=3)
    q = quantize_ternary(w)
    assert set(np.unique(np.asarray(q.data))).issubset({-1, 0, 1})
    # reconstruction error strictly better than the zero approximation
    dq = dequantize(q, jnp.float32)
    assert float(jnp.mean((dq - w) ** 2)) < float(jnp.mean(w ** 2))


def test_qtensor_pytree_roundtrip():
    q = quantize_int8(_rand((8, 128)))
    leaves, treedef = jax.tree.flatten(q)
    q2 = jax.tree.unflatten(treedef, leaves)
    assert q2.precision == "int8" and q2.group == q.group
    assert bool(jnp.all(q2.data == q.data))


def test_qtensor_scan_slicing():
    """Stacked QTensors must slice correctly under lax.scan."""
    w = _rand((4, 16, 128), seed=5)
    q = quantize_int8(w)

    def body(c, q_layer):
        return c, dequantize(q_layer, jnp.float32)

    _, dq = jax.lax.scan(body, 0, q)
    assert dq.shape == (4, 16, 128)
    np.testing.assert_allclose(np.asarray(dq),
                               np.asarray(dequantize(q, jnp.float32)),
                               rtol=1e-6)


def test_nbytes_effective():
    q8 = quantize_int8(_rand((100, 128)))
    assert abs(q8.nbytes_effective() - (100 * 128 + 100 * 2)) < 1
    q4 = quantize_int4(_rand((100, 128)))
    assert q4.nbytes_effective() < q8.nbytes_effective()


def _plan(precisions):
    ds = [BlockDecision(block_index=i, exec_index=i + 1, entropy=float(i),
                        num_parameters=10, precision=p)
          for i, p in enumerate(precisions)]
    return QuantPlan(decisions=ds, mu=0, sigma=0, threshold=0, x_factor=1)


def test_plan_segments():
    p = _plan(["raw", "raw", "int8", "int8", "int4", "raw"])
    assert plan_segments(p) == [("raw", 0, 2), ("int8", 2, 4),
                                ("int4", 4, 5), ("raw", 5, 6)]


def test_apply_plan_stacked_excludes_vectors():
    stacked = {"w": _rand((4, 16, 128)), "ln": jnp.ones((4, 128))}
    seg = apply_plan_stacked(stacked, _plan(["int8"] * 4))
    assert len(seg.segments) == 1
    s = seg.segments[0]
    assert isinstance(s.params["w"], QTensor)
    assert not isinstance(s.params["ln"], QTensor)  # (L, D) stays raw


def test_segmented_bytes_reduction():
    stacked = {"w": _rand((8, 64, 256))}
    raw_bytes = tree_nbytes(stacked)
    seg = apply_plan_stacked(stacked, _plan(["int8"] * 8))
    assert seg.nbytes_effective() < raw_bytes * 0.55
