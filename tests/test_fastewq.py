"""FastEWQ pipeline: dataset, training, plans, ablation."""

import numpy as np
import pytest

from repro.core.dataset import BlockRow, rows_from_plan, to_xy, train_test_split
from repro.core.fastewq import (FastEWQ, evaluate_all_classifiers,
                                feature_ablation, train_fastewq)
from repro.core.policy import BlockDecision, QuantPlan


def _synthetic_rows(n_models=25, seed=0):
    """Paper-like dataset: later blocks + larger blocks quantize more often."""
    rng = np.random.default_rng(seed)
    rows = []
    for m in range(n_models):
        nb = int(rng.integers(8, 40))
        base = rng.uniform(3e7, 5e8)
        for i in range(nb):
            size = int(base * rng.uniform(0.8, 1.2))
            rel = i / nb
            p_q = 0.05 + 0.9 * rel  # exec_index dominates (paper: 66%)
            q = int(rng.random() < p_q)
            rows.append(BlockRow(model_name=f"m{m}", num_blocks=nb,
                                 exec_index=i + 1, num_parameters=size,
                                 quantization_type="8-bit" if q else "raw",
                                 quantized=q))
    return rows


def test_rows_from_plan():
    ds = [BlockDecision(block_index=i, exec_index=i + 1, entropy=1.0,
                        num_parameters=100, precision=p)
          for i, p in enumerate(["raw", "int8", "int4"])]
    plan = QuantPlan(decisions=ds, mu=0, sigma=0, threshold=0, x_factor=1)
    rows = rows_from_plan("m", plan)
    assert [r.quantized for r in rows] == [0, 1, 1]
    assert [r.quantization_type for r in rows] == ["raw", "8-bit", "4-bit"]
    assert all(r.num_blocks == 3 for r in rows)


def test_split_shapes():
    rows = _synthetic_rows(10)
    x, y = to_xy(rows)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3, 0)
    assert len(xte) == round(len(x) * 0.3)
    assert len(xtr) + len(xte) == len(x)


def test_fastewq_beats_majority_baseline():
    rows = _synthetic_rows(30)
    x, y = to_xy(rows)
    _, _, xte, yte = train_test_split(x, y, 0.3, 0)
    fq = train_fastewq(rows, full_dataset=False)
    pred = np.array([fq.predict_quantized(*row) for row in xte])
    acc = (pred == yte).mean()
    majority = max(yte.mean(), 1 - yte.mean())
    assert acc > majority + 0.03, (acc, majority)
    assert acc >= 0.68  # paper: 80% on its dataset; synthetic noise floor


def test_fastewq_plan_variants():
    rows = _synthetic_rows(20)
    fq = train_fastewq(rows, full_dataset=True)
    sizes = [int(2e8)] * 12
    p8 = fq.plan(sizes, variant="8bit-mixed")
    assert len(p8.decisions) == 12
    assert set(p8.precisions()) <= {"raw", "int8"}
    p48 = fq.plan(sizes, variant="4bit/8bit")
    if any(d.quantized for d in p48.decisions):
        # highest-exec-index quantized block became int4
        quantized = [d for d in p48.decisions if d.quantized]
        assert quantized[-1].precision == "int4"


def test_evaluate_all_classifiers_has_six():
    rows = _synthetic_rows(15)
    out = evaluate_all_classifiers(rows)
    assert set(out) == {"logistic regression", "SVM", "random forest", "XGB",
                        "kNN", "Gaussian naive Bayes"}
    for rep in out.values():
        assert 0.3 <= rep["accuracy"] <= 1.0
        assert "confusion" in rep and "auc" in rep
    assert "feature_importances" in out["random forest"]


def test_exec_index_is_top_feature():
    """Paper §4.3: exec_index dominates RF feature importance."""
    rows = _synthetic_rows(30)
    out = evaluate_all_classifiers(rows)
    imp = out["random forest"]["feature_importances"]
    assert imp["exec_index"] == max(imp.values())


def test_feature_ablation_dropping_exec_index_hurts_most():
    rows = _synthetic_rows(30)
    ab = feature_ablation(rows)
    assert ab["all"] >= ab["without_exec_index"]
    drops = {k: ab["all"] - v for k, v in ab.items() if k != "all"}
    assert max(drops, key=drops.get) == "without_exec_index"


def test_save_load_roundtrip(tmp_path):
    rows = _synthetic_rows(10)
    fq = train_fastewq(rows)
    path = str(tmp_path / "fastewq.pkl")
    fq.save(path)
    fq2 = FastEWQ.load(path)
    assert fq2.predict_quantized(2e8, 30, 32) == \
        fq.predict_quantized(2e8, 30, 32)
