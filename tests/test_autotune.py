"""Chunking/block-shape autotuner (kernels/autotune.py, docs/DESIGN.md §12).

Anchor invariants: the cache is DETERMINISTIC (same key -> same config,
byte-stable JSON round-trip), ``autotune`` picks the measured minimum and
leaves it applied while always restoring the pre-sweep knobs on its way
through, and the engine stamps exactly the cache key it applied (or
"untuned") into ServeStats and artifact manifests.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import autotune as at
from repro.kernels.autotune import (AutotuneCache, TunedConfig, autotune,
                                    default_candidates, kv_label,
                                    maybe_apply_tuned, tune_key)
from repro.kernels.decode_attn.ops import get_decode_kv_chunk
from repro.models.model import build
from repro.serving.engine import ServeEngine


@pytest.fixture(autouse=True)
def _knobs_guard():
    """Every test leaves the process-wide knobs exactly as it found them."""
    snap = at.snapshot()
    yield
    at.restore(snap)


def _tiny():
    cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                              num_layers=2)
    model = build(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# TunedConfig / tune_key
# ---------------------------------------------------------------------------

def test_tuned_config_dict_roundtrip_drops_nones():
    c = TunedConfig(decode_kv_chunk=64, qmatmul_bm=256)
    d = c.to_dict()
    assert d == {"decode_kv_chunk": 64, "qmatmul_bm": 256}
    assert TunedConfig.from_dict(d) == c
    # unknown keys from a future cache version are ignored, not fatal
    assert TunedConfig.from_dict({**d, "warp_size": 32}) == c


def test_tune_key_is_sanitized_and_device_scoped():
    key = tune_key("dense", "int4", backend="cpu",
                   device_kind="TPU v5 lite|x")
    assert key == "TPU-v5-lite_x|dense|int4|cpu"
    assert key.count("|") == 3
    # the real-device form resolves without arguments
    assert tune_key("dense", "int8").count("|") == 3


def test_kv_label():
    assert kv_label(None) == "bf16"

    class P:
        precisions = ("int4", "int4")
    assert kv_label(P) == "int4"
    P.precisions = ("int8", "int4")
    assert kv_label(P) == "mixed"


# ---------------------------------------------------------------------------
# cache: determinism + byte-stable persistence
# ---------------------------------------------------------------------------

def test_cache_json_roundtrip_byte_stable(tmp_path):
    path = str(tmp_path / "at.json")
    cache = AutotuneCache(path)
    cache.put("cpu|dense|int4|cpu", TunedConfig(decode_kv_chunk=512),
              metrics={"cost_s": 0.5})
    cache.put("cpu|dense|int8|cpu", TunedConfig(decode_kv_chunk=64))
    cache.save()
    first = open(path).read()
    # reload -> identical configs, and saving again rewrites identical bytes
    re = AutotuneCache(path)
    assert re.get("cpu|dense|int4|cpu") == TunedConfig(decode_kv_chunk=512)
    assert re.get("cpu|dense|int8|cpu") == TunedConfig(decode_kv_chunk=64)
    assert re.metrics("cpu|dense|int4|cpu") == {"cost_s": 0.5}
    re.save()
    assert open(path).read() == first
    # same key always resolves to the same config across loads
    again = AutotuneCache(path)
    assert again.get("cpu|dense|int4|cpu") == re.get("cpu|dense|int4|cpu")


def test_cache_version_mismatch_starts_empty(tmp_path):
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"version": 999, "configs": {"k": {}}}))
    assert AutotuneCache(str(path)).get("k") is None


def test_cache_missing_key_is_none(tmp_path):
    assert AutotuneCache(str(tmp_path / "x.json")).get("nope") is None


# ---------------------------------------------------------------------------
# snapshot / apply / restore
# ---------------------------------------------------------------------------

def test_apply_and_restore_roundtrip():
    snap = at.snapshot()
    at.apply_config(TunedConfig(decode_kv_chunk=96), key="k1")
    assert get_decode_kv_chunk() == 96
    assert at.current_stamp() == "k1"
    at.restore(snap)
    assert get_decode_kv_chunk() == snap["decode_kv_chunk"]
    assert at.current_stamp() == "untuned"


def test_apply_none_fields_leave_knobs_alone():
    before = at.snapshot()
    at.apply_config(TunedConfig(), key="noop")
    assert at.snapshot() == before


def test_autotune_picks_measured_min_and_persists(tmp_path):
    cache = AutotuneCache(str(tmp_path / "c.json"))
    cands = [TunedConfig(decode_kv_chunk=w) for w in (64, 128, 256)]
    costs = {64: 3.0, 128: 1.0, 256: 2.0}

    def bench(config):
        # the candidate must be APPLIED while its bench runs
        assert get_decode_kv_chunk() == config.decode_kv_chunk
        return costs[config.decode_kv_chunk]

    best, results = autotune("cpu|dense|int8|cpu", bench, cands, cache=cache)
    assert best == TunedConfig(decode_kv_chunk=128)
    assert [r["cost_s"] for r in results] == [3.0, 1.0, 2.0]
    # winner left applied + stamped; cache persisted for a fresh process
    assert get_decode_kv_chunk() == 128
    assert at.current_stamp() == "cpu|dense|int8|cpu"
    re = AutotuneCache(str(tmp_path / "c.json"))
    assert re.get("cpu|dense|int8|cpu") == best
    assert re.metrics("cpu|dense|int8|cpu")["cost_s"] == 1.0


def test_autotune_restores_knobs_when_bench_raises():
    before = at.snapshot()

    def bench(config):
        raise RuntimeError("oom")

    with pytest.raises(RuntimeError):
        autotune("k", bench, [TunedConfig(decode_kv_chunk=1024)], save=False,
                 cache=AutotuneCache("/nonexistent/never-written.json"))
    assert at.snapshot() == before


def test_maybe_apply_tuned_hit_miss(tmp_path):
    path = str(tmp_path / "c.json")
    key = tune_key("dense", "int4")
    cache = AutotuneCache(path)
    cache.put(key, TunedConfig(decode_kv_chunk=512))
    cache.save()
    assert maybe_apply_tuned("dense", "int4", path=path) == key
    assert get_decode_kv_chunk() == 512
    # miss: unknown precision label -> untuned, knobs untouched
    assert maybe_apply_tuned("dense", "mixed", path=path) == "untuned"
    assert get_decode_kv_chunk() == 512


def test_default_candidates_cover_library_default():
    for prec in ("bf16", "int8", "int4"):
        widths = {c.decode_kv_chunk for c in default_candidates(prec, "cpu")}
        assert 256 in widths, prec   # the untuned default is in every grid
    assert 1024 in {c.decode_kv_chunk
                    for c in default_candidates("int4", "cpu")}
    tpu = default_candidates("int8", "tpu")
    assert any(c.qmatmul_bm for c in tpu)   # TPU sweeps megakernel tiles


# ---------------------------------------------------------------------------
# engine integration: the tuned stamp
# ---------------------------------------------------------------------------

def test_engine_applies_tuned_config_and_stamps(tmp_path, monkeypatch):
    path = str(tmp_path / "c.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    key = tune_key("dense", "int8")
    cache = AutotuneCache(path)
    cache.put(key, TunedConfig(decode_kv_chunk=32))
    cache.save()
    cfg, model, params = _tiny()
    eng = ServeEngine(model, params, max_seq=24, kv_precision="int8")
    assert eng.tuned == key
    assert get_decode_kv_chunk() == 32
    # opt-out serves library defaults and says so
    eng2 = ServeEngine(model, params, max_seq=24, kv_precision="int8",
                       autotune=False)
    assert eng2.tuned == "untuned"


def test_engine_untuned_on_cache_miss(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "empty.json"))
    cfg, model, params = _tiny()
    eng = ServeEngine(model, params, max_seq=24)
    assert eng.tuned == "untuned"
    out = eng.generate(jax.random.randint(jax.random.PRNGKey(0), (1, 4), 0,
                                          cfg.vocab_size), 4)
    assert out.tokens.shape[1] == 8


def test_tuned_and_untuned_engines_agree_greedy(tmp_path, monkeypatch):
    """A tuned kv_chunk changes the sweep schedule, never the tokens."""
    path = str(tmp_path / "c.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    cfg, model, params = _tiny()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    base = ServeEngine(model, params, max_seq=24, kv_precision="int8",
                       autotune=False).generate(prompts, 8)
    cache = AutotuneCache(path)
    cache.put(tune_key("dense", "int8"), TunedConfig(decode_kv_chunk=5))
    cache.save()
    eng = ServeEngine(model, params, max_seq=24, kv_precision="int8")
    assert eng.tuned != "untuned"
    out = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(base.tokens),
                                  np.asarray(out.tokens))


FAMILY_ARCHS = (("dense", "llama3.2-3b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-2.7b"), ("encdec", "whisper-medium"))


@pytest.mark.parametrize("family,arch", FAMILY_ARCHS)
def test_tuned_config_greedy_identity_all_families(family, arch,
                                                   tmp_path, monkeypatch):
    """Applying a tuned config (odd chunk widths included) must never
    change greedy output on any family — tuning reschedules, never
    renumbers."""
    path = str(tmp_path / "c.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, num_layers=4 if cfg.family == "hybrid" else 2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(5),
                                   (2, cfg.encoder_seq, cfg.d_model))
    base = ServeEngine(model, params, max_seq=24, kv_precision="int8",
                       autotune=False).generate(prompts, 8, frames=frames)
    cache = AutotuneCache(path)
    # the engine looks up its RESOLVED kv label — "int8" where the family
    # carries a KV cache, "bf16" where it doesn't (pure SSM): seed both
    for label in ("int8", "bf16"):
        cache.put(tune_key(family, label),
                  TunedConfig(decode_kv_chunk=3, q_chunk=4, kv_chunk=8,
                              chunk_threshold=4))
    cache.save()
    eng = ServeEngine(model, params, max_seq=24, kv_precision="int8")
    assert eng.tuned != "untuned"
    out = eng.generate(prompts, 8, frames=frames)
    np.testing.assert_array_equal(np.asarray(base.tokens),
                                  np.asarray(out.tokens))
