"""From-scratch classifier suite + metrics + paired statistics."""

import numpy as np
import pytest

from repro.core.classifiers.boosted import GradientBoosting
from repro.core.classifiers.gnb import GaussianNB
from repro.core.classifiers.knn import KNN
from repro.core.classifiers.linear import LinearSVM, LogisticRegression
from repro.core.classifiers.metrics import (auc, classification_report,
                                            cohens_d, confusion,
                                            effect_size_label,
                                            paired_t_test, roc_curve,
                                            significance_label)
from repro.core.classifiers.rf import RandomForest
from repro.core.classifiers.scaler import StandardScaler
from repro.core.classifiers.tree import DecisionTree


def _separable(n=300, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.normal(-1.5, 1.0, (n // 2, 3))
    x1 = rng.normal(1.5, 1.0, (n - n // 2, 3))
    x = np.vstack([x0, x1])
    y = np.array([0] * (n // 2) + [1] * (n - n // 2))
    idx = rng.permutation(n)
    return x[idx], y[idx]


ALL = [DecisionTree, RandomForest, LogisticRegression, LinearSVM, KNN,
       GaussianNB, GradientBoosting]


@pytest.mark.parametrize("cls", ALL)
def test_classifier_learns_separable(cls):
    x, y = _separable()
    xtr, ytr, xte, yte = x[:200], y[:200], x[200:], y[200:]
    sc = StandardScaler()
    clf = cls().fit(sc.fit_transform(xtr), ytr)
    acc = (clf.predict(sc.transform(xte)) == yte).mean()
    assert acc >= 0.9, f"{cls.__name__}: {acc}"


def test_rf_nonlinear_beats_linear():
    """XOR-ish data: tree ensembles must beat linear models (paper's
    rationale for random forest)."""
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (400, 2))
    y = ((x[:, 0] > 0) ^ (x[:, 1] > 0)).astype(int)
    xtr, ytr, xte, yte = x[:300], y[:300], x[300:], y[300:]
    rf = RandomForest(n_estimators=40, max_depth=6).fit(xtr, ytr)
    lr = LogisticRegression().fit(xtr, ytr)
    acc_rf = (rf.predict(xte) == yte).mean()
    acc_lr = (lr.predict(xte) == yte).mean()
    assert acc_rf > 0.85 and acc_rf > acc_lr + 0.2


def test_scaler():
    x = np.array([[1.0, 10.0], [3.0, 10.0], [5.0, 10.0]])
    z = StandardScaler().fit_transform(x)
    np.testing.assert_allclose(z.mean(0), [0, 0], atol=1e-12)
    np.testing.assert_allclose(z[:, 0].std(), 1.0, atol=1e-12)
    assert np.all(z[:, 1] == 0)  # zero-variance feature stays finite


def test_confusion_and_report():
    y_true = np.array([1, 1, 1, 0, 0, 0, 1, 0])
    y_pred = np.array([1, 1, 0, 0, 0, 1, 1, 0])
    c = confusion(y_true, y_pred)
    assert c == {"tp": 3, "tn": 3, "fp": 1, "fn": 1}
    rep = classification_report(y_true, y_pred)
    assert abs(rep["accuracy"] - 6 / 8) < 1e-12
    assert abs(rep["classes"][1]["precision"] - 3 / 4) < 1e-12
    assert abs(rep["classes"][1]["recall"] - 3 / 4) < 1e-12


def test_auc_perfect_and_random():
    y = np.array([0, 0, 1, 1])
    assert abs(auc(y, np.array([0.1, 0.2, 0.8, 0.9])) - 1.0) < 1e-9
    assert abs(auc(y, np.array([0.9, 0.8, 0.2, 0.1])) - 0.0) < 1e-9
    fpr, tpr, _ = roc_curve(y, np.array([0.1, 0.2, 0.8, 0.9]))
    assert fpr[0] == 0 and tpr[-1] == 1


def test_paired_t_test_and_cohens_d():
    a = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    r = paired_t_test(a, a)
    assert r["t"] == 0.0 and r["p"] == 1.0
    # constant shift has zero-variance differences -> degenerate t (p=1)
    r2 = paired_t_test(a + 1.0, a)
    assert r2["p"] == 1.0 and r2["mean_diff"] == 1.0
    rng = np.random.default_rng(0)
    b = a + 2.0 + rng.normal(0, 0.1, 5)
    r3 = paired_t_test(b, a)
    assert r3["p"] < 0.05 and r3["t"] > 0
    d = cohens_d(np.array([10.0, 11, 12, 9, 10]), np.array([0.0, 1, 2, -1, 0]))
    assert effect_size_label(d) == "large"
    assert significance_label(0.03) == "significant"
    assert significance_label(0.07) == "marginally significant"
    assert significance_label(0.5) == "not significant"


def test_t_test_p_value_accuracy():
    """Compare the betainc-based p-value against known t-table values:
    t=2.776, df=4 -> p=0.05 (two-sided)."""
    from repro.core.classifiers.metrics import _t_sf
    assert abs(_t_sf(2.776, 4) - 0.05) < 2e-3
    assert abs(_t_sf(1.96, 1000) - 0.05) < 2e-3


def test_feature_importances_sum_to_one():
    x, y = _separable()
    rf = RandomForest(n_estimators=10, max_depth=5).fit(x, y)
    assert abs(rf.feature_importances_.sum() - 1.0) < 1e-9
