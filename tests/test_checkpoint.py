"""Atomic sharded checkpointing + auto-resume + fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.quant.quantize import quantize_int8
from repro.runtime.fault import PreemptionGuard, StepWatchdog, retry


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "q": quantize_int8(jnp.ones((4, 128)) * 0.3)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 7, tree, extra={"step": 7})
    restored, extra = ckpt.restore(tmp_path, tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["q"].precision == "int8"
    np.testing.assert_array_equal(np.asarray(restored["q"].data),
                                  np.asarray(tree["q"].data))


def test_latest_and_retention(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in [10, 20, 30, 40]:
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 40
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000030", "step_00000040"]


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    ckpt.save(tmp_path, 1, tree)
    # fake a torn write: directory without .complete marker
    bad = tmp_path / "step_00000099"
    bad.mkdir()
    assert ckpt.latest_step(tmp_path) == 1


def test_train_loop_auto_resume(tmp_path):
    cfg = get_config("olmo-1b", smoke=True)
    from repro.train.loop import train
    run = RunConfig(steps=6, learning_rate=1e-3, warmup_steps=1, remat=False,
                    checkpoint_dir=str(tmp_path), checkpoint_every=3)
    r1 = train(cfg, run, batch=2, seq=16, log_fn=lambda s: None)
    assert ckpt.latest_step(tmp_path) == 6
    # continue to 10 steps from the checkpoint: loop resumes at step 6
    run2 = RunConfig(steps=10, learning_rate=1e-3, warmup_steps=1,
                     remat=False, checkpoint_dir=str(tmp_path),
                     checkpoint_every=3)
    logs = []
    r2 = train(cfg, run2, batch=2, seq=16, log_fn=logs.append)
    assert any("resumed from step 6" in l for l in logs)
    assert len(r2["losses"]) == 4  # steps 6..9


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, grace_steps=1)
    for _ in range(10):
        assert wd.observe(1.0) == "ok"
    assert wd.observe(5.0) == "straggler"
    assert not wd.should_reshard()
    for _ in range(5):
        wd.observe(5.0)  # ewma catches up eventually; force repeats
    assert len(wd.stragglers) >= 1


def test_retry_bounded():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry(flaky, attempts=5, base_delay=0.0) == "ok"
    assert len(calls) == 3
    with pytest.raises(RuntimeError):
        retry(lambda: (_ for _ in ()).throw(RuntimeError("x")),
              attempts=2, base_delay=0.0)


def test_preemption_guard_flag():
    import signal
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert not g.preempted
        os.kill(os.getpid(), signal.SIGUSR1)
        assert g.preempted
