"""End-to-end behaviour: train -> EWQ analyze -> quantize -> serve.

The full paper pipeline at CPU scale, asserting the paper's qualitative
claims hold mechanically: mixed EWQ preserves quality far better than
uniform 4-bit at a real memory reduction, and FastEWQ reproduces most of
EWQ's decisions from metadata alone.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.planner import plan_model
from repro.models.model import build
from repro.quant.apply import tree_nbytes
from repro.serving.engine import ServeEngine
from repro.serving.quantized import apply_plan_to_params, fastewq_metadata_plan
from repro.serving.scheduler import synthetic_stream
from repro.train.loop import evaluate, train


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                              num_layers=4)
    run = RunConfig(steps=120, learning_rate=2e-3, warmup_steps=10,
                    remat=False, schedule="cosine")
    res = train(cfg, run, batch=16, seq=64, log_fn=lambda s: None)
    return cfg, res["model"], res["params"], res["losses"]


def test_training_learns(trained):
    _, _, _, losses = trained
    assert losses[-1] < losses[0] - 0.5  # clearly below initial ~ln(512)


def test_ewq_plan_nontrivial(trained):
    cfg, model, params, _ = trained
    plan = plan_model(model, params, variant="4bit/8bit")
    counts = plan.counts()
    assert len(plan.decisions) == 1 + cfg.num_layers
    assert counts["raw"] >= 1                    # high-entropy kept raw
    assert counts["int8"] + counts["int4"] >= 1  # something quantized


def test_quantized_eval_quality_ordering(trained):
    """raw ~ ewq-mixed << uniform-4bit perplexity (paper Table 6 shape)."""
    cfg, model, params, _ = trained
    ev_raw = evaluate(model, params, batch=8, seq=64, steps=4)

    plan_mixed = plan_model(model, params, variant="8bit-mixed")
    p_mixed = apply_plan_to_params(model, params, plan_mixed)
    ev_mixed = evaluate(model, p_mixed, batch=8, seq=64, steps=4)

    plan_4bit = plan_model(model, params, variant="4bit")
    p_4bit = apply_plan_to_params(model, params, plan_4bit)
    ev_4bit = evaluate(model, p_4bit, batch=8, seq=64, steps=4)

    # mixed stays close to raw; uniform 4-bit degrades at least as much
    mixed_delta = abs(ev_mixed["loss"] - ev_raw["loss"])
    bit4_delta = abs(ev_4bit["loss"] - ev_raw["loss"])
    assert mixed_delta < 0.05, (ev_raw, ev_mixed)
    assert bit4_delta >= mixed_delta - 1e-6


def test_memory_reduction(trained):
    cfg, model, params, _ = trained
    plan = plan_model(model, params, variant="4bit/8bit")
    pq = apply_plan_to_params(model, params, plan)
    raw = tree_nbytes(params)
    q = tree_nbytes(pq["embed"]) + pq["layers"].nbytes_effective() + \
        tree_nbytes(pq["final"])
    assert q < raw  # strictly smaller
    p8 = plan_model(model, params, variant="8bit")
    pq8 = apply_plan_to_params(model, params, p8)
    q8 = tree_nbytes(pq8["embed"]) + pq8["layers"].nbytes_effective() + \
        tree_nbytes(pq8["final"])
    assert q8 < raw * 0.62  # uniform int8 cuts ~2x


def test_fastewq_agreement_with_ewq(trained):
    """FastEWQ (metadata-only) agrees with EWQ on a majority of blocks."""
    cfg, model, params, _ = trained
    ewq = plan_model(model, params, variant="8bit-mixed")
    fast = fastewq_metadata_plan(cfg, "8bit-mixed")
    agree = np.mean([a.quantized == b.quantized
                     for a, b in zip(ewq.decisions, fast.decisions)])
    assert agree >= 0.4  # tiny model; paper gets 80% at scale


def test_serve_raw_vs_quantized_generate(trained):
    cfg, model, params, _ = trained
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    raw_engine = ServeEngine(model, params, max_seq=24)
    out_raw = raw_engine.generate(prompts, 8)
    plan = plan_model(model, params, variant="8bit-mixed")
    q_engine = ServeEngine(model, params, max_seq=24, plan=plan)
    out_q = q_engine.generate(prompts, 8)
    assert out_raw.tokens.shape == out_q.tokens.shape == (2, 16)
    assert bool(jnp.isfinite(out_q.logprobs).all())
    # int8-mixed decode should mostly agree with raw greedy decode
    agree = float((out_raw.tokens[:, 8:] == out_q.tokens[:, 8:]).mean())
    assert agree >= 0.5
    assert q_engine.weight_bytes() < raw_engine.weight_bytes()


def test_serve_stream_quantized(trained):
    """Continuous batching on the trained+quantized model: every request in
    a simulated stream drains through 2 slots and matches a dedicated
    single-request generate (greedy)."""
    cfg, model, params, _ = trained
    plan = plan_model(model, params, variant="8bit-mixed")
    engine = ServeEngine(model, params, max_seq=24, plan=plan)
    reqs = synthetic_stream(4, vocab_size=cfg.vocab_size, prompt_len=8,
                            max_new_tokens=8, arrival_rate=0.5, seed=5)
    outs, stats = engine.serve(reqs, num_slots=2, chunk=4)
    assert [o.rid for o in outs] == [0, 1, 2, 3]
    assert 0.0 < stats.occupancy <= 1.0
    for r, o in zip(reqs, outs):
        ref = engine.generate(jnp.asarray(r.prompt)[None], r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(ref.tokens[0]), o.tokens)
