"""Algorithms 1 & 2: resource-constrained distribution (paper §3.4/§4)."""

import pytest

from repro.core.cluster import (Machine, cluster_budget, fastewq_resource_adjust,
                                fit_plan_to_hbm, optimize_distribution)
from repro.core.entropy import BlockEntropy
from repro.core.policy import decide


def _plan(entropies, size=1_000_000):
    blocks = [BlockEntropy(block_index=i, exec_index=i + 1, entropy=h,
                           num_parameters=size, per_matrix={})
              for i, h in enumerate(entropies)]
    return decide(blocks, x_factor=1.0)


def test_budget_is_min_of_mem_and_disk():
    m = Machine("a", 100, 60)
    assert m.budget == 60
    assert cluster_budget([m, Machine("b", 10, 20)]) == 70


def test_unquantized_when_it_fits():
    plan = _plan([1.0, 5.0, 9.0])  # 3 blocks x 1M params x 2B = 6MB raw
    res = optimize_distribution(plan, [Machine("m0", 10e6, 10e6)])
    assert res["fits"]
    assert all(d.precision == "raw" for d in res["plan"].decisions)


def test_promote_highest_entropy_first():
    plan = _plan([1.0, 5.0, 9.0])
    # budget fits the EWQ plan with room for ONE promotion but not all raw
    base = plan.total_bytes()
    budget = base + 1_000_000 * (2.0 - 1.015625) + 1000  # one int8->raw
    res = optimize_distribution(plan, [Machine("m0", budget, budget)])
    precs = res["plan"].precisions()
    assert res["fits"]
    # highest-entropy quantized block got promoted first
    assert res["plan"].total_bytes() <= budget


def test_demote_lowest_entropy_until_fit():
    plan = _plan([1.0, 5.0, 9.0])
    tight = plan.total_bytes() * 0.8
    res = optimize_distribution(plan, [Machine("m0", tight, tight)])
    precs = res["plan"].precisions()
    assert "ternary" in precs or "int4" in precs
    assert res["plan"].total_bytes() <= tight or not res["fits"]


def test_placement_respects_machine_budgets():
    plan = _plan([1.0, 5.0, 9.0, 2.0], size=500_000)
    machines = [Machine("a", 2.2e6, 2.2e6), Machine("b", 2.2e6, 2.2e6)]
    res = optimize_distribution(plan, machines)
    placed = sorted(i for blocks in res["placement"].values() for i in blocks)
    assert placed == [0, 1, 2, 3]
    for name, blocks in res["placement"].items():
        used = sum(res["plan"].decisions[i].nbytes() for i in blocks)
        assert used <= 2.2e6 + 1e-6


def test_fastewq_adjust_promotes_by_exec_index():
    plan = _plan([3.0, 3.0, 3.0, 3.0])
    # start from all-int8 (classifier preselection)
    plan = plan.with_precisions(["int8"] * 4)
    budget = plan.total_bytes() + 1_000_000 * (2.0 - 1.015625) + 100
    res = fastewq_resource_adjust(plan, [Machine("m", budget, budget)])
    precs = res["plan"].precisions()
    # the LOWEST exec_index block is promoted first
    assert precs[0] == "raw"
    assert precs[1:] == ["int8"] * 3


def test_fastewq_adjust_demotes_highest_exec_index():
    plan = _plan([3.0] * 4).with_precisions(["int8"] * 4)
    tight = plan.total_bytes() * 0.85
    res = fastewq_resource_adjust(plan, [Machine("m", tight, tight)])
    precs = res["plan"].precisions()
    assert precs[-1] in ("int4", "ternary")  # demotion starts at the end
    assert precs[0] == "int8"


def test_fit_plan_to_hbm_returns_fitting_plan():
    plan = _plan([1.0, 5.0, 9.0], size=10_000_000)
    fitted = fit_plan_to_hbm(plan, hbm_bytes_per_device=2e6, devices=16,
                             reserved_fraction=0.25)
    assert fitted.total_bytes() <= 2e6 * 0.75 * 16
