"""Continuous-batching serving engine: scheduler/batch/loop mechanics.

Mechanics-only tests on a tiny untrained model (fast): slot lifecycle,
masked sampling, per-slot stop conditions, and quantized-vs-raw parity
through the fused chunked decode loop. Mesh-parallel serving parity
(docs/DESIGN.md §9) runs in a subprocess under 8 virtual CPU devices.
"""

import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.model import build
from repro.serving import batch as B
from repro.serving.engine import ServeEngine
from repro.serving.quantized import fastewq_metadata_plan
from repro.serving.scheduler import Request, Scheduler, synthetic_stream


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                              num_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, b, p, seed=3):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0,
                              cfg.vocab_size, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_lifecycle():
    s = Scheduler(num_slots=2)
    for i, arrival in enumerate((0, 0, 5)):
        s.submit(Request(rid=i, prompt=np.zeros(4, np.int32),
                         max_new_tokens=4, arrival_step=arrival))
    assert s.free_slots() == [0, 1]
    r0 = s.next_ready(clock=0)
    s.assign(0, r0, clock=0)
    assert s.next_ready(clock=0).rid == 1          # rid 2 not arrived yet
    assert s.next_arrival() == 5
    assert s.num_active == 1 and s.free_slots() == [1]
    out = s.complete(0, np.arange(6, dtype=np.int32), np.zeros(2), "length", 8)
    assert out.rid == 0 and out.admitted_step == 0 and out.finished_step == 8
    assert s.free_slots() == [0, 1] and not s.all_done()


# ---------------------------------------------------------------------------
# fused loop vs per-token loop; slot reuse; masked sampling
# ---------------------------------------------------------------------------

def test_fused_loop_matches_stepwise_greedy(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    prompts = _prompts(cfg, 2, 8)
    fused = engine.generate(prompts, 8, chunk=3)   # chunk not dividing max_new
    step = engine.generate_stepwise(prompts, 8)
    np.testing.assert_array_equal(np.asarray(fused.tokens),
                                  np.asarray(step.tokens))
    np.testing.assert_allclose(np.asarray(fused.logprobs),
                               np.asarray(step.logprobs), atol=1e-4)


def test_slot_reuse_after_finish(tiny):
    """3 requests through 1 slot: each drains through the same slot and must
    match a dedicated single-request generate (insert fully resets state)."""
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = [Request(rid=i, prompt=np.asarray(_prompts(cfg, 1, 6, seed=i)[0]),
                    max_new_tokens=6) for i in range(3)]
    outs, stats = engine.serve(reqs, num_slots=1, chunk=4)
    assert [o.rid for o in outs] == [0, 1, 2]
    assert stats.occupancy == 1.0
    for r, o in zip(reqs, outs):
        ref = engine.generate(jnp.asarray(r.prompt)[None], r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(ref.tokens[0]), o.tokens)
        np.testing.assert_allclose(np.asarray(ref.logprobs[0]), o.logprobs,
                                   atol=1e-4)


def test_masked_sampling_never_advances_done_slots(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    prompts = _prompts(cfg, 2, 8)
    cache, last_logits = engine.prefill(prompts)
    state = B.DecodeState(
        cache=cache._replace(pos=jnp.full((2,), 8, jnp.int32)),
        last_logits=last_logits.astype(jnp.float32),
        tokens=jnp.pad(prompts, ((0, 0), (0, 16))),
        lengths=jnp.full((2,), 8, jnp.int32),
        max_len=jnp.full((2,), 16, jnp.int32),
        done=jnp.array([True, False]),               # slot 0 already done
        active=jnp.array([True, True]),
        logprobs=jnp.zeros((2, 24), jnp.float32),
        key=jax.random.PRNGKey(0),
        temperature=jnp.zeros((2,), jnp.float32),
        top_k=jnp.zeros((2,), jnp.int32),
        top_p=jnp.ones((2,), jnp.float32))
    out = engine._chunk_fn(4)(engine.params, state)
    # done slot: frozen buffers, zero logprobs written
    np.testing.assert_array_equal(np.asarray(out.tokens[0]),
                                  np.asarray(state.tokens[0]))
    assert int(out.lengths[0]) == 8
    assert float(jnp.abs(out.logprobs[0]).sum()) == 0.0
    assert bool(out.done[0])
    # live slot advanced by the full chunk
    assert int(out.lengths[1]) == 12
    assert not bool(out.done[1])


def test_eos_stops_slot_early(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, 1, 8)
    ref = ServeEngine(model, params, max_seq=24).generate(prompts, 6)
    first = int(ref.tokens[0, 8])                    # greedy first new token
    engine = ServeEngine(model, params, max_seq=24, eos_id=first)
    out, stats = engine.serve(
        [Request(rid=0, prompt=np.asarray(prompts[0]), max_new_tokens=6)],
        num_slots=1, chunk=6)
    assert out[0].finish_reason == "eos"
    assert len(out[0].generated) == 1 and out[0].generated[0] == first


def test_degenerate_args_raise(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    req = [Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4)]
    with pytest.raises(ValueError):
        engine.serve(req, num_slots=1, chunk=0)
    with pytest.raises(ValueError):
        engine.serve(req, num_slots=0, chunk=4)
    with pytest.raises(ValueError):
        engine.generate(_prompts(cfg, 1, 4), 0)


def test_idle_gap_admission_not_counted_as_refill(tiny):
    """An admission into a fully idle engine (after a clock fast-forward)
    is not a continuous-batching refill."""
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = [Request(rid=0, prompt=np.asarray(_prompts(cfg, 1, 6, seed=0)[0]),
                    max_new_tokens=4, arrival_step=0),
            Request(rid=1, prompt=np.asarray(_prompts(cfg, 1, 6, seed=1)[0]),
                    max_new_tokens=4, arrival_step=100)]
    outs, stats = engine.serve(reqs, num_slots=2, chunk=4)
    assert len(outs) == 2
    assert stats.admissions == 0


def test_continuous_admission_and_occupancy(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = synthetic_stream(6, vocab_size=cfg.vocab_size, prompt_len=8,
                            max_new_tokens=8, arrival_rate=0.5, seed=1)
    outs, stats = engine.serve(reqs, num_slots=2, chunk=4)
    assert len(outs) == 6
    assert stats.admissions > 0                      # slots refilled mid-run
    assert 0.0 < stats.occupancy <= 1.0
    for r, o in zip(reqs, outs):
        assert o.rid == r.rid
        assert len(o.tokens) == len(r.prompt) + r.max_new_tokens
        assert o.finish_reason == "length"
        assert np.isfinite(o.logprobs).all()


# ---------------------------------------------------------------------------
# quantized parity through the new engine
# ---------------------------------------------------------------------------

def test_quantized_vs_raw_logprob_parity(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, 2, 8)
    raw = ServeEngine(model, params, max_seq=24)
    plan = fastewq_metadata_plan(cfg, "8bit-mixed")
    q = ServeEngine(model, params, max_seq=24, plan=plan)
    out_raw = raw.generate(prompts, 8)
    out_q = q.generate(prompts, 8)
    assert out_raw.tokens.shape == out_q.tokens.shape == (2, 16)
    agree = float((out_raw.tokens[:, 8:] == out_q.tokens[:, 8:]).mean())
    assert agree >= 0.5
    # where greedy tokens agree, chosen-token logprobs must be close
    same = np.asarray(out_raw.tokens[:, 8:] == out_q.tokens[:, 8:])
    lp_r = np.asarray(out_raw.logprobs)[same]
    lp_q = np.asarray(out_q.logprobs)[same]
    np.testing.assert_allclose(lp_r, lp_q, atol=0.05)
    assert q.weight_bytes() < raw.weight_bytes()


# ---------------------------------------------------------------------------
# mesh-parallel serving (docs/DESIGN.md §9) — 8 virtual devices, subprocess
# ---------------------------------------------------------------------------

def _run_subprocess(code: str):
    """XLA_FLAGS must be set before jax import, hence a subprocess."""
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_sharded_serve_matches_single_device():
    """serve() on a 1x8 TP mesh returns the same tokens and (atol) logprobs
    as a single-device engine, for transformer AND hybrid under a mixed
    quantized plan; per-device weight bytes genuinely shrink."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.model import build
        from repro.launch.mesh import make_mesh
        from repro.serving.engine import ServeEngine
        from repro.serving.quantized import fastewq_metadata_plan
        from repro.serving.scheduler import Request

        mesh = make_mesh((1, 8), ("data", "model"))
        for arch, layers_over in (("llama3.2-3b", {"num_layers": 2}),
                                  ("zamba2-2.7b", {})):
            cfg = dataclasses.replace(get_config(arch, smoke=True),
                                      dtype="float32", **layers_over)
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            plan = fastewq_metadata_plan(cfg, "4bit/8bit")
            reqs = [Request(rid=i, prompt=np.asarray(jax.random.randint(
                        jax.random.PRNGKey(i), (6,), 0, cfg.vocab_size,
                        dtype=jnp.int32)), max_new_tokens=5)
                    for i in range(3)]
            ref = ServeEngine(model, params, max_seq=24, plan=plan)
            outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
            eng = ServeEngine(model, params, max_seq=24, plan=plan, mesh=mesh)
            outs, _ = eng.serve(reqs, num_slots=2, chunk=4)
            for a, b in zip(outs, outs_ref):
                np.testing.assert_array_equal(a.tokens, b.tokens)
                np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)
            per_dev = eng.weight_bytes_per_device()
            single = ref.weight_bytes_per_device()
            assert per_dev < 0.5 * single, (arch, per_dev, single)
            print("OK", arch, per_dev / single)
    """)
    assert out.count("OK") == 2


def test_sharded_artifact_cold_boot_lands_sharded():
    """from_artifact(mesh=...) restores every weight leaf already sharded
    (no replicated materialization) and generates identically to the
    in-memory engine; a pure-DP mesh (no "model" axis) also serves."""
    out = _run_subprocess("""
        import dataclasses, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.model import build
        from repro.launch.mesh import make_mesh
        from repro.serving.engine import ServeEngine
        from repro.serving.quantized import explicit_plan
        from repro.quant.compiler import compile_plan, save_artifact
        from repro.quant.qtypes import QTensor

        cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                                  dtype="float32", num_layers=2)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        compiled = compile_plan(model, params,
                                explicit_plan(cfg, ["int8", "int4"]))
        d = tempfile.mkdtemp()
        mesh = make_mesh((1, 8), ("data", "model"))
        save_artifact(d, compiled, mesh=mesh)
        art = ServeEngine.from_artifact(model, d, max_seq=24, mesh=mesh)
        # every quantized payload is committed to the 8-device mesh, and at
        # least the stacked attention weights are genuinely TP-split
        qts = [l for l in jax.tree.leaves(
                   art.params["layers"],
                   is_leaf=lambda x: isinstance(x, QTensor))
               if isinstance(l, QTensor)]
        assert qts
        assert all(len(q.data.sharding.device_set) == 8 for q in qts)
        assert any("model" in q.data.sharding.spec for q in qts)
        mem = ServeEngine(model, compiled.params, max_seq=24)
        p = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                               cfg.vocab_size, dtype=jnp.int32)
        o_mem, o_art = mem.generate(p, 6), art.generate(p, 6)
        np.testing.assert_array_equal(np.asarray(o_mem.tokens),
                                      np.asarray(o_art.tokens))
        np.testing.assert_allclose(np.asarray(o_mem.logprobs),
                                   np.asarray(o_art.logprobs), atol=1e-4)
        dp = make_mesh((8,), ("data",))
        o_dp = ServeEngine(model, compiled.params, max_seq=24,
                           mesh=dp).generate(p, 6)
        np.testing.assert_array_equal(np.asarray(o_mem.tokens),
                                      np.asarray(o_dp.tokens))
        print("OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# SLO scheduler mechanics (docs/DESIGN.md §14) — host-side, no model
# ---------------------------------------------------------------------------

def _req(rid, priority=1, arrival=0, **kw):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), max_new_tokens=4,
                   arrival_step=arrival, priority=priority, **kw)


def test_scheduler_priority_ordering():
    """Ready queue pops by (priority, arrival, submit order)."""
    s = Scheduler(num_slots=1)
    for r in (_req(0, priority=2), _req(1, priority=0), _req(2, priority=1),
              _req(3, priority=0)):
        s.submit(r)
    order = [s.next_ready(0).rid for _ in range(4)]
    assert order == [1, 3, 2, 0]   # priority-0 pair FIFO, then 1, then 2


def test_scheduler_queue_timeout_and_cancel():
    s = Scheduler(num_slots=1)
    s.submit(_req(0, queue_timeout_steps=3))
    s.submit(_req(1))
    s.cancel(1)
    s.expire(clock=5)                          # both past their drop point
    assert s.next_ready(5) is None and s.all_done()
    reasons = {o.rid: o.finish_reason for o in s.finished}
    assert reasons == {0: "timeout", 1: "cancelled"}
    assert all(o.admitted_step == -1 for o in s.finished)
    assert s.timeouts == 1 and s.cancels == 1


def test_scheduler_deadline_applies_while_running():
    s = Scheduler(num_slots=1)
    s.submit(_req(0, deadline_steps=6))
    s.assign(0, s.next_ready(0), clock=0)
    assert s.drop_reason(s.active_slots()[0][1], clock=3) is None
    assert s.drop_reason(s.active_slots()[0][1], clock=6) == "deadline"


def test_scheduler_preempt_requeues_and_counts():
    s = Scheduler(num_slots=2)
    s.submit(_req(0, priority=2))
    s.submit(_req(1, priority=1))
    s.assign(0, s.next_ready(0), clock=0)      # rid 1 pops first (pri 1)
    s.assign(1, s.next_ready(0), clock=0)      # then rid 0 (pri 2)
    s.submit(_req(2, priority=0, arrival=4))
    # victim for a priority-0 waiter: the lowest-priority slot (rid 0)
    vslot = s.preempt_victim(0)
    assert vslot == 1
    # no victim for a priority-2 waiter (nothing strictly below it)
    assert s.preempt_victim(2) is None
    victim = s.preempt(vslot)
    assert victim.rid == 0 and s.preemptions == 1
    assert s.free_slots() == [1]
    # the victim is back in the ready queue at its own priority
    assert s.next_ready(4).rid == 2            # priority 0 first
    assert s.next_ready(4).rid == 0
    out = s.complete(0, np.arange(8, dtype=np.int32), np.zeros(4),
                     "length", 8)
    assert out.preempted == 0


def test_scheduler_reserve_activate_split():
    """A reserved (prefilling) slot is neither free nor active."""
    s = Scheduler(num_slots=2)
    s.submit(_req(0))
    s.reserve(0, s.next_ready(0), clock=0)
    assert s.free_slots() == [1]
    assert s.num_active == 0 and s.num_reserved == 1
    assert not s.all_done()
    assert s.reserved_request(0).rid == 0
    s.activate(0)
    assert s.num_active == 1 and s.num_reserved == 0


def test_synthetic_stream_poisson_deterministic():
    kw = dict(vocab_size=64, prompt_len=4, max_new_tokens=4,
              arrival_rate=0.5, poisson=True, seed=9)
    a = synthetic_stream(12, **kw)
    b = synthetic_stream(12, **kw)
    arr = [r.arrival_step for r in a]
    assert arr == [r.arrival_step for r in b]      # seeded: reproducible
    assert arr == sorted(arr) and arr[0] == 0
    assert arr != [int(i / 0.5) for i in range(12)]   # not fixed spacing
    fixed = synthetic_stream(12, **{**kw, "poisson": False})
    assert [r.arrival_step for r in fixed] == [int(i / 0.5)
                                               for i in range(12)]
    pri = synthetic_stream(8, **{**kw, "priorities": (0, 1)})
    assert [r.priority for r in pri] == [0, 1] * 4


# ---------------------------------------------------------------------------
# chunked prefill interleaving (docs/DESIGN.md §14)
# ---------------------------------------------------------------------------

def _family_requests(cfg, n=4, prompt_len=12, max_new=6, arrival=0.5):
    rng = np.random.RandomState(17)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=(prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new,
                    arrival_step=int(i / arrival) if arrival else 0)
            for i in range(n)]


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "encdec"])
def test_chunked_prefill_matches_monolithic(trained, family):
    """Greedy serve() with prefill_chunk (non-dividing) is token-identical
    to monolithic prefill on every family."""
    cfg, model, params = trained[family]
    engine = ServeEngine(model, params, max_seq=24)
    reqs = _family_requests(cfg)
    outs_ref, _ = engine.serve(reqs, num_slots=2, chunk=4)
    outs_c, stats = engine.serve(reqs, num_slots=2, chunk=4,
                                 prefill_chunk=5)
    assert stats.prefill_chunks >= len(reqs) * 2   # 12 tokens / 5 -> 3 each
    for a, b in zip(outs_ref, outs_c):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)


@pytest.mark.parametrize("kv_precision", ["int8", "int4"])
def test_chunked_prefill_quantized_kv_parity(trained, kv_precision):
    """Chunked prefill fills a bf16 batch=1 cache; quantization happens at
    insert — so int8/int4 KV engines stay token-identical to monolithic."""
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=24,
                         kv_precision=kv_precision)
    reqs = _family_requests(cfg)
    outs_ref, _ = engine.serve(reqs, num_slots=2, chunk=4)
    outs_c, _ = engine.serve(reqs, num_slots=2, chunk=4, prefill_chunk=5)
    for a, b in zip(outs_ref, outs_c):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_chunked_prefill_spec_decode_parity(trained):
    """Spec engines admit chunked-prefilled slots exactly like monolithic
    ones (pos == lengths marks the fresh slot either way)."""
    from repro.serving.spec import SpecConfig
    cfg, model, params = trained["dense"]
    reqs = _family_requests(cfg)
    ref = ServeEngine(model, params, max_seq=24)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=2)
    spec = ServeEngine(model, params, max_seq=24, spec=SpecConfig(k=2))
    outs_s, _ = spec.serve(reqs, num_slots=2, chunk=2, prefill_chunk=5)
    for a, b in zip(outs_ref, outs_s):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_engine_level_prefill_chunk_default(trained):
    """ServeEngine(prefill_chunk=...) applies when serve() doesn't pass
    one; serve(prefill_chunk=...) still overrides."""
    cfg, model, params = trained["dense"]
    reqs = _family_requests(cfg, n=2)
    ref = ServeEngine(model, params, max_seq=24)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    eng = ServeEngine(model, params, max_seq=24, prefill_chunk=4)
    outs, stats = eng.serve(reqs, num_slots=2, chunk=4)
    assert stats.prefill_chunks > 0
    for a, b in zip(outs_ref, outs):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_seq=24, prefill_chunk=0)


# ---------------------------------------------------------------------------
# SLO serving end-to-end: priorities, preemption, timeout, cancellation
# ---------------------------------------------------------------------------

def test_serve_priority_admission_order(trained):
    """With one slot, a later-arriving priority-0 request is admitted ahead
    of earlier priority-1 traffic that is still queued."""
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=24)
    reqs = _family_requests(cfg, n=4, arrival=0)      # rids 0-2 at step 0
    for r in reqs:
        r.priority = 1
    reqs[3].priority = 0
    reqs[3].arrival_step = 2                  # arrives after rid 0 admits
    outs, _ = engine.serve(reqs, num_slots=1, chunk=4)
    admits = {o.rid: o.admitted_step for o in outs}
    assert admits[0] == 0                     # first FIFO pick took the slot
    assert admits[3] < min(admits[1], admits[2])
    assert all(o.priority == r.priority for o, r in zip(outs, reqs))


def test_serve_preemption_roundtrip(trained):
    """A priority-0 arrival evicts the running priority-1 request
    (SLOConfig.preempt); the victim re-prefills from scratch and its final
    tokens are identical to an uncontended run."""
    from repro.serving.scheduler import SLOConfig
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=32)
    rng = np.random.RandomState(23)
    long_req = Request(rid=0, prompt=rng.randint(
        0, cfg.vocab_size, size=(8,)).astype(np.int32),
        max_new_tokens=16, priority=1)
    urgent = Request(rid=1, prompt=rng.randint(
        0, cfg.vocab_size, size=(8,)).astype(np.int32),
        max_new_tokens=4, arrival_step=4, priority=0)
    outs, stats = engine.serve([long_req, urgent], num_slots=1, chunk=4,
                               slo=SLOConfig(preempt=True))
    assert stats.preemptions == 1
    assert outs[0].preempted == 1 and outs[1].preempted == 0
    assert outs[0].finish_reason == "length"
    # the urgent request ran while the victim waited
    assert outs[1].admitted_step <= outs[0].admitted_step
    ref, _ = engine.serve([long_req], num_slots=1, chunk=4)
    np.testing.assert_array_equal(outs[0].tokens, ref[0].tokens)


def test_serve_queue_timeout_drops_without_slot(trained):
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=24)
    reqs = _family_requests(cfg, n=2, arrival=0, max_new=12)
    reqs[1].queue_timeout_steps = 4            # can't outwait rid 0
    outs, stats = engine.serve(reqs, num_slots=1, chunk=4)
    assert outs[0].finish_reason == "length"
    assert outs[1].finish_reason == "timeout"
    assert outs[1].admitted_step == -1 and len(outs[1].generated) == 0
    assert stats.timeouts == 1


def test_serve_cancel_running_keeps_partial_tokens(trained):
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=40)
    reqs = _family_requests(cfg, n=1, arrival=0, max_new=24)
    reqs[0].cancel_at_step = 8                 # mid-decode
    outs, stats = engine.serve(reqs, num_slots=1, chunk=4)
    assert outs[0].finish_reason == "cancelled"
    assert 0 < len(outs[0].generated) < 24     # partial output kept
    assert len(outs[0].logprobs) == len(outs[0].generated)
    assert stats.cancelled == 1
    # the partial tokens are a prefix of the uncontended run
    ref, _ = engine.serve(
        [dataclasses.replace(reqs[0], cancel_at_step=None)],
        num_slots=1, chunk=4)
    n = len(outs[0].tokens)
    np.testing.assert_array_equal(outs[0].tokens, ref[0].tokens[:n])


def test_serve_deadline_applies_while_running(trained):
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=40)
    reqs = _family_requests(cfg, n=1, arrival=0, max_new=24)
    reqs[0].deadline_steps = 8
    outs, _ = engine.serve(reqs, num_slots=1, chunk=4)
    assert outs[0].finish_reason == "deadline"
    assert 0 < len(outs[0].generated) < 24


def test_queue_delay_reported_separately_from_ttft(trained):
    """A request that waits for a slot reports queue_delay; TTFT starts at
    dequeue, so the wait does not inflate it."""
    cfg, model, params = trained["dense"]
    engine = ServeEngine(model, params, max_seq=24)
    reqs = _family_requests(cfg, n=3, arrival=0, max_new=8)
    outs, stats = engine.serve(reqs, num_slots=1, chunk=4)
    assert outs[0].queue_delay_steps == 0
    assert all(o.queue_delay_steps > 0 for o in outs[1:])   # waited
    assert all(o.queue_delay_s is not None and o.ttft_s is not None
               for o in outs)
    assert stats.queue_delay_p95_s >= stats.queue_delay_p50_s >= 0.0


# ---------------------------------------------------------------------------
# DP x TP replica serving (docs/DESIGN.md §14) — 8 virtual devices
# ---------------------------------------------------------------------------

def test_replica_router_is_load_aware():
    from repro.serving.replica import ReplicaServe
    r = ReplicaServe.__new__(ReplicaServe)
    r.engines = [object(), object()]
    reqs = [Request(rid=0, prompt=np.zeros(10, np.int32), max_new_tokens=10),
            Request(rid=1, prompt=np.zeros(2, np.int32), max_new_tokens=2),
            Request(rid=2, prompt=np.zeros(2, np.int32), max_new_tokens=2),
            Request(rid=3, prompt=np.zeros(2, np.int32), max_new_tokens=2)]
    buckets = r.route(reqs)
    # rid 0 weighs 20; rids 1-3 (4 each) all land on the other replica
    assert [q.rid for q in buckets[0]] == [0]
    assert [q.rid for q in buckets[1]] == [1, 2, 3]


def test_dp_replica_serve_matches_tp_only():
    """ReplicaServe on a 2x4 (data, model) mesh is greedy token-identical
    to the same stream on a 1x8 TP-only engine; per-replica occupancy and
    load-aware assignments are reported."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.model import build
        from repro.launch.mesh import make_mesh, split_data_replicas
        from repro.serving.engine import ServeEngine
        from repro.serving.quantized import fastewq_metadata_plan
        from repro.serving.replica import ReplicaServe
        from repro.serving.scheduler import synthetic_stream

        cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                                  dtype="float32", num_layers=2)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        plan = fastewq_metadata_plan(cfg, "4bit/8bit")
        reqs = synthetic_stream(6, vocab_size=cfg.vocab_size, prompt_len=8,
                                max_new_tokens=6, arrival_rate=0.5, seed=2)
        tp = ServeEngine(model, params, max_seq=24, plan=plan,
                         mesh=make_mesh((1, 8), ("data", "model")))
        outs_tp, _ = tp.serve(reqs, num_slots=2, chunk=4)

        mesh = make_mesh((2, 4), ("data", "model"))
        subs = split_data_replicas(mesh)
        assert len(subs) == 2
        assert all(dict(m.shape) == {"data": 1, "model": 4} for m in subs)
        rep = ReplicaServe([ServeEngine(model, params, max_seq=24,
                                        plan=plan, mesh=m) for m in subs])
        outs_dp, rstats = rep.serve(reqs, num_slots=2, chunk=4,
                                    prefill_chunk=3)
        assert rstats.replicas == 2
        assert sum(rstats.assignments) == len(reqs)
        assert all(n > 0 for n in rstats.assignments)  # both carried load
        assert len(rstats.occupancy_per_replica) == 2
        assert all(0.0 < o <= 1.0
                   for o in rstats.occupancy_per_replica)
        assert rstats.aggregate.generated_tokens == sum(
            st.generated_tokens for st in rstats.per_replica)
        for a, b in zip(outs_tp, outs_dp):
            assert a.rid == b.rid
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_slotted_decode_matches_lockstep(tiny):
    """Vector-pos decode (slotted cache) equals scalar-pos decode."""
    cfg, model, params = tiny
    b, s = 3, 10
    toks = _prompts(cfg, b, 1)
    ls, cs = model.decode_step(params, model.init_cache(b, s), toks)
    lv, cv = model.decode_step(params, model.slotted_cache(b, s), toks)
    np.testing.assert_allclose(np.asarray(ls, np.float32),
                               np.asarray(lv, np.float32), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(cv.pos), np.ones(b))
