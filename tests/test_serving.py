"""Continuous-batching serving engine: scheduler/batch/loop mechanics.

Mechanics-only tests on a tiny untrained model (fast): slot lifecycle,
masked sampling, per-slot stop conditions, and quantized-vs-raw parity
through the fused chunked decode loop. Mesh-parallel serving parity
(docs/DESIGN.md §9) runs in a subprocess under 8 virtual CPU devices.
"""

import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.model import build
from repro.serving import batch as B
from repro.serving.engine import ServeEngine
from repro.serving.quantized import fastewq_metadata_plan
from repro.serving.scheduler import Request, Scheduler, synthetic_stream


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                              num_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, b, p, seed=3):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0,
                              cfg.vocab_size, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_lifecycle():
    s = Scheduler(num_slots=2)
    for i, arrival in enumerate((0, 0, 5)):
        s.submit(Request(rid=i, prompt=np.zeros(4, np.int32),
                         max_new_tokens=4, arrival_step=arrival))
    assert s.free_slots() == [0, 1]
    r0 = s.next_ready(clock=0)
    s.assign(0, r0, clock=0)
    assert s.next_ready(clock=0).rid == 1          # rid 2 not arrived yet
    assert s.next_arrival() == 5
    assert s.num_active == 1 and s.free_slots() == [1]
    out = s.complete(0, np.arange(6, dtype=np.int32), np.zeros(2), "length", 8)
    assert out.rid == 0 and out.admitted_step == 0 and out.finished_step == 8
    assert s.free_slots() == [0, 1] and not s.all_done()


# ---------------------------------------------------------------------------
# fused loop vs per-token loop; slot reuse; masked sampling
# ---------------------------------------------------------------------------

def test_fused_loop_matches_stepwise_greedy(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    prompts = _prompts(cfg, 2, 8)
    fused = engine.generate(prompts, 8, chunk=3)   # chunk not dividing max_new
    step = engine.generate_stepwise(prompts, 8)
    np.testing.assert_array_equal(np.asarray(fused.tokens),
                                  np.asarray(step.tokens))
    np.testing.assert_allclose(np.asarray(fused.logprobs),
                               np.asarray(step.logprobs), atol=1e-4)


def test_slot_reuse_after_finish(tiny):
    """3 requests through 1 slot: each drains through the same slot and must
    match a dedicated single-request generate (insert fully resets state)."""
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = [Request(rid=i, prompt=np.asarray(_prompts(cfg, 1, 6, seed=i)[0]),
                    max_new_tokens=6) for i in range(3)]
    outs, stats = engine.serve(reqs, num_slots=1, chunk=4)
    assert [o.rid for o in outs] == [0, 1, 2]
    assert stats.occupancy == 1.0
    for r, o in zip(reqs, outs):
        ref = engine.generate(jnp.asarray(r.prompt)[None], r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(ref.tokens[0]), o.tokens)
        np.testing.assert_allclose(np.asarray(ref.logprobs[0]), o.logprobs,
                                   atol=1e-4)


def test_masked_sampling_never_advances_done_slots(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    prompts = _prompts(cfg, 2, 8)
    cache, last_logits = engine.prefill(prompts)
    state = B.DecodeState(
        cache=cache._replace(pos=jnp.full((2,), 8, jnp.int32)),
        last_logits=last_logits.astype(jnp.float32),
        tokens=jnp.pad(prompts, ((0, 0), (0, 16))),
        lengths=jnp.full((2,), 8, jnp.int32),
        max_len=jnp.full((2,), 16, jnp.int32),
        done=jnp.array([True, False]),               # slot 0 already done
        active=jnp.array([True, True]),
        logprobs=jnp.zeros((2, 24), jnp.float32),
        key=jax.random.PRNGKey(0),
        temperature=jnp.zeros((2,), jnp.float32),
        top_k=jnp.zeros((2,), jnp.int32),
        top_p=jnp.ones((2,), jnp.float32))
    out = engine._chunk_fn(4)(engine.params, state)
    # done slot: frozen buffers, zero logprobs written
    np.testing.assert_array_equal(np.asarray(out.tokens[0]),
                                  np.asarray(state.tokens[0]))
    assert int(out.lengths[0]) == 8
    assert float(jnp.abs(out.logprobs[0]).sum()) == 0.0
    assert bool(out.done[0])
    # live slot advanced by the full chunk
    assert int(out.lengths[1]) == 12
    assert not bool(out.done[1])


def test_eos_stops_slot_early(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, 1, 8)
    ref = ServeEngine(model, params, max_seq=24).generate(prompts, 6)
    first = int(ref.tokens[0, 8])                    # greedy first new token
    engine = ServeEngine(model, params, max_seq=24, eos_id=first)
    out, stats = engine.serve(
        [Request(rid=0, prompt=np.asarray(prompts[0]), max_new_tokens=6)],
        num_slots=1, chunk=6)
    assert out[0].finish_reason == "eos"
    assert len(out[0].generated) == 1 and out[0].generated[0] == first


def test_degenerate_args_raise(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    req = [Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4)]
    with pytest.raises(ValueError):
        engine.serve(req, num_slots=1, chunk=0)
    with pytest.raises(ValueError):
        engine.serve(req, num_slots=0, chunk=4)
    with pytest.raises(ValueError):
        engine.generate(_prompts(cfg, 1, 4), 0)


def test_idle_gap_admission_not_counted_as_refill(tiny):
    """An admission into a fully idle engine (after a clock fast-forward)
    is not a continuous-batching refill."""
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = [Request(rid=0, prompt=np.asarray(_prompts(cfg, 1, 6, seed=0)[0]),
                    max_new_tokens=4, arrival_step=0),
            Request(rid=1, prompt=np.asarray(_prompts(cfg, 1, 6, seed=1)[0]),
                    max_new_tokens=4, arrival_step=100)]
    outs, stats = engine.serve(reqs, num_slots=2, chunk=4)
    assert len(outs) == 2
    assert stats.admissions == 0


def test_continuous_admission_and_occupancy(tiny):
    cfg, model, params = tiny
    engine = ServeEngine(model, params, max_seq=24)
    reqs = synthetic_stream(6, vocab_size=cfg.vocab_size, prompt_len=8,
                            max_new_tokens=8, arrival_rate=0.5, seed=1)
    outs, stats = engine.serve(reqs, num_slots=2, chunk=4)
    assert len(outs) == 6
    assert stats.admissions > 0                      # slots refilled mid-run
    assert 0.0 < stats.occupancy <= 1.0
    for r, o in zip(reqs, outs):
        assert o.rid == r.rid
        assert len(o.tokens) == len(r.prompt) + r.max_new_tokens
        assert o.finish_reason == "length"
        assert np.isfinite(o.logprobs).all()


# ---------------------------------------------------------------------------
# quantized parity through the new engine
# ---------------------------------------------------------------------------

def test_quantized_vs_raw_logprob_parity(tiny):
    cfg, model, params = tiny
    prompts = _prompts(cfg, 2, 8)
    raw = ServeEngine(model, params, max_seq=24)
    plan = fastewq_metadata_plan(cfg, "8bit-mixed")
    q = ServeEngine(model, params, max_seq=24, plan=plan)
    out_raw = raw.generate(prompts, 8)
    out_q = q.generate(prompts, 8)
    assert out_raw.tokens.shape == out_q.tokens.shape == (2, 16)
    agree = float((out_raw.tokens[:, 8:] == out_q.tokens[:, 8:]).mean())
    assert agree >= 0.5
    # where greedy tokens agree, chosen-token logprobs must be close
    same = np.asarray(out_raw.tokens[:, 8:] == out_q.tokens[:, 8:])
    lp_r = np.asarray(out_raw.logprobs)[same]
    lp_q = np.asarray(out_q.logprobs)[same]
    np.testing.assert_allclose(lp_r, lp_q, atol=0.05)
    assert q.weight_bytes() < raw.weight_bytes()


# ---------------------------------------------------------------------------
# mesh-parallel serving (docs/DESIGN.md §9) — 8 virtual devices, subprocess
# ---------------------------------------------------------------------------

def _run_subprocess(code: str):
    """XLA_FLAGS must be set before jax import, hence a subprocess."""
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_sharded_serve_matches_single_device():
    """serve() on a 1x8 TP mesh returns the same tokens and (atol) logprobs
    as a single-device engine, for transformer AND hybrid under a mixed
    quantized plan; per-device weight bytes genuinely shrink."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.model import build
        from repro.launch.mesh import make_mesh
        from repro.serving.engine import ServeEngine
        from repro.serving.quantized import fastewq_metadata_plan
        from repro.serving.scheduler import Request

        mesh = make_mesh((1, 8), ("data", "model"))
        for arch, layers_over in (("llama3.2-3b", {"num_layers": 2}),
                                  ("zamba2-2.7b", {})):
            cfg = dataclasses.replace(get_config(arch, smoke=True),
                                      dtype="float32", **layers_over)
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            plan = fastewq_metadata_plan(cfg, "4bit/8bit")
            reqs = [Request(rid=i, prompt=np.asarray(jax.random.randint(
                        jax.random.PRNGKey(i), (6,), 0, cfg.vocab_size,
                        dtype=jnp.int32)), max_new_tokens=5)
                    for i in range(3)]
            ref = ServeEngine(model, params, max_seq=24, plan=plan)
            outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
            eng = ServeEngine(model, params, max_seq=24, plan=plan, mesh=mesh)
            outs, _ = eng.serve(reqs, num_slots=2, chunk=4)
            for a, b in zip(outs, outs_ref):
                np.testing.assert_array_equal(a.tokens, b.tokens)
                np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)
            per_dev = eng.weight_bytes_per_device()
            single = ref.weight_bytes_per_device()
            assert per_dev < 0.5 * single, (arch, per_dev, single)
            print("OK", arch, per_dev / single)
    """)
    assert out.count("OK") == 2


def test_sharded_artifact_cold_boot_lands_sharded():
    """from_artifact(mesh=...) restores every weight leaf already sharded
    (no replicated materialization) and generates identically to the
    in-memory engine; a pure-DP mesh (no "model" axis) also serves."""
    out = _run_subprocess("""
        import dataclasses, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.model import build
        from repro.launch.mesh import make_mesh
        from repro.serving.engine import ServeEngine
        from repro.serving.quantized import explicit_plan
        from repro.quant.compiler import compile_plan, save_artifact
        from repro.quant.qtypes import QTensor

        cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                                  dtype="float32", num_layers=2)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        compiled = compile_plan(model, params,
                                explicit_plan(cfg, ["int8", "int4"]))
        d = tempfile.mkdtemp()
        mesh = make_mesh((1, 8), ("data", "model"))
        save_artifact(d, compiled, mesh=mesh)
        art = ServeEngine.from_artifact(model, d, max_seq=24, mesh=mesh)
        # every quantized payload is committed to the 8-device mesh, and at
        # least the stacked attention weights are genuinely TP-split
        qts = [l for l in jax.tree.leaves(
                   art.params["layers"],
                   is_leaf=lambda x: isinstance(x, QTensor))
               if isinstance(l, QTensor)]
        assert qts
        assert all(len(q.data.sharding.device_set) == 8 for q in qts)
        assert any("model" in q.data.sharding.spec for q in qts)
        mem = ServeEngine(model, compiled.params, max_seq=24)
        p = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                               cfg.vocab_size, dtype=jnp.int32)
        o_mem, o_art = mem.generate(p, 6), art.generate(p, 6)
        np.testing.assert_array_equal(np.asarray(o_mem.tokens),
                                      np.asarray(o_art.tokens))
        np.testing.assert_allclose(np.asarray(o_mem.logprobs),
                                   np.asarray(o_art.logprobs), atol=1e-4)
        dp = make_mesh((8,), ("data",))
        o_dp = ServeEngine(model, compiled.params, max_seq=24,
                           mesh=dp).generate(p, 6)
        np.testing.assert_array_equal(np.asarray(o_mem.tokens),
                                      np.asarray(o_dp.tokens))
        print("OK")
    """)
    assert "OK" in out


def test_slotted_decode_matches_lockstep(tiny):
    """Vector-pos decode (slotted cache) equals scalar-pos decode."""
    cfg, model, params = tiny
    b, s = 3, 10
    toks = _prompts(cfg, b, 1)
    ls, cs = model.decode_step(params, model.init_cache(b, s), toks)
    lv, cv = model.decode_step(params, model.slotted_cache(b, s), toks)
    np.testing.assert_allclose(np.asarray(ls, np.float32),
                               np.asarray(lv, np.float32), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(cv.pos), np.ones(b))
