"""Per-arch smoke tests (reduced configs): forward + train step + decode.

One forward/train step on CPU asserting output shapes + no NaNs, per the
assignment; plus decode-vs-teacher-forced parity for one arch per family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import synthetic_batch
from repro.launch.steps import make_optimizer
from repro.models.model import build
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    return synthetic_batch(cfg, batch=b, seq=s, step=0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch, remat=False)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    if cfg.num_experts:
        assert "moe_aux_loss" in aux


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    run = RunConfig(steps=2, learning_rate=1e-3, warmup_steps=1, remat=False)
    opt = make_optimizer(run)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, run))
    params2, opt_state2, metrics = step(params, opt_state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert int(cache2.pos) == 1


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "zamba2-2.7b", "whisper-medium",
                                  "grok-1-314b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode == teacher-forced forward (f32, tight tol).
    MoE needs headroom capacity: prefill routes B*S tokens jointly while
    decode routes B per step, so capacity-drop sets differ at cf=1.25."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32",
                              capacity_factor=8.0)
    model = build(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits_tf, _ = model.apply(params, batch, remat=False)

    cache = model.init_cache(b, s)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode(params, batch["frames"], cfg, remat=False)
        ck, cv = encdec.precompute_cross_kv(params, enc_out, cfg)
        cache = cache._replace(cross_k=ck, cross_v=cv)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t+1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_tf - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_tf))) + 1e-6
    assert err / scale < 5e-5, f"{arch}: rel err {err/scale}"


def test_block_params_counts():
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        blocks = model.block_params(model.init(KEY))
        expected = 1 + cfg.num_layers  # embed + layers
        if cfg.family == "encdec":
            expected += cfg.num_encoder_layers
        if cfg.family == "hybrid":
            expected += 1  # shared block
        assert len(blocks) == expected, arch


def test_param_count_matches_init():
    import numpy as np
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        params = jax.eval_shape(lambda k: model.init(k), KEY)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        assert actual == cfg.param_count(), \
            f"{arch}: analytic {cfg.param_count()} vs init {actual}"
