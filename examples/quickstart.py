"""Quickstart: the EWQ pipeline in ~40 lines.

Train a small LM on synthetic data, analyze per-block entropy, build the
paper's 4bit/8bit mixed plan, quantize, and compare quality + size.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.planner import analyze, plan_model
from repro.quant.apply import tree_nbytes
from repro.serving.quantized import apply_plan_to_params
from repro.train.loop import evaluate, train

# 1. Train a reduced llama3.2-style model on the synthetic LM stream.
cfg = get_config("llama3.2-3b", smoke=True)
run = RunConfig(steps=80, learning_rate=2e-3, warmup_steps=8, remat=False)
result = train(cfg, run, batch=16, seq=64)
model, params = result["model"], result["params"]

# 2. EWQ entropy analysis (paper §3.1-3.2): one entropy per block.
entropies = analyze(model.block_params(params))
print("\nblock entropies (exec_index: H):")
for b in entropies:
    print(f"  {b.exec_index:3d}: {b.entropy:.4f}  ({b.num_parameters:,} params)")

# 3. Selection criterion T = mu - sigma (paper §3.3) -> mixed-precision plan.
plan = plan_model(model, params, variant="4bit/8bit")
print(f"\nmu={plan.mu:.4f} sigma={plan.sigma:.4f} T={plan.threshold:.4f}")
print("plan:", {d.exec_index: d.precision for d in plan.decisions})

# 4. Apply the plan and compare quality + bytes.
params_q = apply_plan_to_params(model, params, plan)
ev_raw = evaluate(model, params, batch=8, seq=64)
ev_q = evaluate(model, params_q, batch=8, seq=64)
raw_b = tree_nbytes(params)
q_b = (tree_nbytes(params_q["embed"]) + params_q["layers"].nbytes_effective()
       + tree_nbytes(params_q["final"]))
print(f"\nraw   : ppl {ev_raw['perplexity']:8.3f}  {raw_b/2**20:6.2f} MiB")
print(f"EWQ   : ppl {ev_q['perplexity']:8.3f}  {q_b/2**20:6.2f} MiB "
      f"(-{(1-q_b/raw_b)*100:.1f}%)")
