"""EWQ/FastEWQ quantized serving with batched requests.

Compares three deployments of the same model:
  raw bf16 | EWQ 4bit/8bit mixed (weights analyzed) | FastEWQ (O(1), no
  weight analysis — the paper's deployment story).

  PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.planner import plan_model
from repro.serving.engine import ServeEngine
from repro.serving.quantized import fastewq_metadata_plan
from repro.train.loop import train

cfg = get_config("yi-9b", smoke=True)
run = RunConfig(steps=60, learning_rate=2e-3, warmup_steps=6, remat=False)
result = train(cfg, run, batch=16, seq=64, log_every=30)
model, params = result["model"], result["params"]

prompts = jax.random.randint(jax.random.PRNGKey(7), (4, 12), 0,
                             cfg.vocab_size, dtype=jnp.int32)

deployments = {
    "raw": None,
    "ewq 4bit/8bit": plan_model(model, params, variant="4bit/8bit"),
    "fastewq (O(1))": fastewq_metadata_plan(cfg, "8bit-mixed"),
}

ref_tokens = None
for name, plan in deployments.items():
    engine = ServeEngine(model, params, max_seq=32, plan=plan)
    t0 = time.perf_counter()
    out = engine.generate(prompts, 12)
    dt = time.perf_counter() - t0
    if ref_tokens is None:
        ref_tokens = out.tokens
    agree = float((out.tokens[:, -12:] == ref_tokens[:, -12:]).mean())
    print(f"{name:16s} weights {engine.weight_bytes()/2**20:6.2f} MiB  "
          f"agree-with-raw {agree:5.1%}  "
          f"mean logprob {float(out.logprobs.mean()):7.3f}  ({dt:.1f}s)")
