"""End-to-end training driver: a ~100M-param model for a few hundred steps
with checkpointing, auto-resume and fault-tolerant runtime.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the deliverable (b) end-to-end driver. The ~100M config is a scaled
llama3.2 (12 layers, d_model 768) that trains on CPU in minutes; the same
code path drives the production mesh under multi-host JAX.
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig, RunConfig
from repro.train.loop import evaluate, train

CFG_100M = ModelConfig(
    name="llama-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
    d_ff=2048, vocab_size=32000, rope_theta=500000.0, tie_embeddings=True,
    max_seq_len=1024,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()

    print(f"params: {CFG_100M.param_count()/1e6:.1f}M")
    run = RunConfig(steps=args.steps, learning_rate=3e-4, warmup_steps=30,
                    schedule="cosine", checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=100, remat=False)
    result = train(CFG_100M, run, batch=args.batch, seq=args.seq,
                   log_every=20)
    ev = evaluate(result["model"], result["params"], batch=args.batch,
                  seq=args.seq)
    print(f"\nfinal eval: loss {ev['loss']:.4f}, ppl {ev['perplexity']:.2f}")
    print(f"stragglers observed: {len(result['stragglers'])}")
    print(f"resume anytime: same command (checkpoints in "
          f"{args.checkpoint_dir})")


if __name__ == "__main__":
    main()
