"""Algorithm 1/2 walk-through: resource-constrained block distribution.

Plans the paper's deployment decision for llama3.2-3b (full config,
analytic sizes — no weights needed) across a heterogeneous cluster, at
three budget levels, then shows the TPU-native per-device HBM fitting.

  PYTHONPATH=src python examples/cluster_deploy.py
"""

from repro.configs.registry import get_config
from repro.core.cluster import Machine, fit_plan_to_hbm, optimize_distribution
from repro.core.entropy import BlockEntropy
from repro.core.policy import decide
from repro.serving.quantized import fastewq_metadata_plan

cfg = get_config("llama3.2-3b")
# analytic per-block sizes + a synthetic entropy profile (FastEWQ-style
# deployment: no weights downloaded)
layer_params = (cfg.param_count() - cfg.padded_vocab * cfg.d_model) \
    // cfg.num_layers
blocks = [BlockEntropy(block_index=i, exec_index=i + 1,
                       entropy=5.0 + 0.05 * abs(i - cfg.num_layers // 3),
                       num_parameters=layer_params, per_matrix={})
          for i in range(cfg.num_layers)]
plan = decide(blocks, x_factor=1.0)
raw_gb = plan.raw_bytes() / 2**30
print(f"{cfg.name}: {cfg.num_layers} blocks, raw {raw_gb:.2f} GB\n")

for budget_gb in [raw_gb * 1.2, raw_gb * 0.75, raw_gb * 0.35]:
    machines = [Machine(f"m{i}", budget_gb / 4 * 2**30, budget_gb / 4 * 2**30)
                for i in range(4)]
    res = optimize_distribution(plan, machines)
    c = res["plan"].counts()
    print(f"cluster budget {budget_gb:6.2f} GB -> fits={res['fits']} "
          f"size={res['total_bytes']/2**30:6.2f} GB  "
          f"mix raw/int8/int4/ternary = "
          f"{c['raw']}/{c['int8']}/{c['int4']}/{c['ternary']}")
    loads = {m: len(b) for m, b in res["placement"].items()}
    print(f"  placement (blocks per machine): {loads}")

fitted = fit_plan_to_hbm(plan, hbm_bytes_per_device=16 * 2**30, devices=1,
                         reserved_fraction=0.5)
print(f"\nTPU-native: fit to one v5e HBM (16GB, 50% reserved): "
      f"{fitted.counts()} -> {fitted.total_bytes()/2**30:.2f} GB")
